"""Serving-loop smoke benchmark: paired warm/cold trace replays.

    python -m benchmarks.serve_smoke [--scale quick|default|paper]
                                     [--seed 0] [--out results/ci]
                                     [--crash-at N]

Replays ONE deterministic arrival trace (``repro.serve.gct_trace``)
through two ``RightsizingService`` instances — the production
warm-started configuration and a ``warm_start=False`` cold control —
plus a third crash-and-recover leg (checkpoint mid-replay, discard the
service, restore, finish), and emits the ``serve`` telemetry blob the
service-regression gate (``benchmarks.check_service``) diffs against
``results/golden/solver_stats.json``:

  * sustained ``requests_per_s`` and ``p50/p99_replan_s`` of the warm
    (production) run;
  * ``dispatches_per_tick`` (the micro-batching invariant: every tick
    funnels its touched fleets through ONE FleetEngine dispatch);
  * ``median_iters_warm`` vs ``median_iters_cold_control`` — warm
    re-solves of perturbed fleets must stay cheaper than the cold
    control's matched re-solves;
  * warm-vs-cold parity of ``proposed_cost_total`` within
    ``ServiceConfig.cost_drift_bound_pct`` (both runs propose from the
    same per-tick problems, so the drift is pure epsilon-optimal
    vertex noise);
  * crash-and-recover determinism: the interrupted replay's
    ``recovered_total_cost`` / ``recovered_proposed_cost_total`` must
    equal the uninterrupted warm run's (snapshots round-trip floats
    exactly), and its warm-lane fraction must survive the restart.
    ``--crash-at N`` picks the crash tick (default: mid-replay;
    ``--crash-at 0`` disables the leg and its gate).

``benchmarks.run --serve-trace`` merges this blob under the ``"serve"``
key of ``<out>/solver_stats.json`` so one artifact feeds both the
convergence and service gates.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

_SCALES = {
    # fleets, requests, n0, m, push_per_tick
    "quick": (4, 220, 36, 5, 12),
    "default": (4, 220, 36, 5, 12),
    "paper": (6, 400, 48, 6, 16),
}


def _warm_frac(report: dict) -> float:
    lanes = report["warm_lanes"] + report["cold_lanes"]
    return round(report["warm_lanes"] / lanes, 4) if lanes else 0.0


def serve_smoke(scale: str = "quick", seed: int = 0,
                crash_at: int | None = None) -> dict:
    """Run the paired warm/cold replay (plus the crash-and-recover leg
    unless ``crash_at == 0``) and return the ``serve`` blob."""
    from repro.serve import (RightsizingService, ServiceConfig, TraceSpec,
                             gct_trace, replay, replay_with_crash)

    fleets, requests, n0, m, push = _SCALES[scale]
    spec = TraceSpec(fleets=fleets, requests=requests, n0=n0, m=m,
                     seed=seed)
    trace = gct_trace(spec)
    reports = {}
    walls = {}
    for label, warm in [("warm", True), ("cold", False)]:
        svc = RightsizingService(
            config=ServiceConfig(warm_start=warm))
        t0 = time.perf_counter()
        reports[label] = replay(svc, list(trace), push_per_tick=push)
        walls[label] = round(time.perf_counter() - t0, 2)
    w, c = reports["warm"], reports["cold"]
    drift = (abs(w["proposed_cost_total"] - c["proposed_cost_total"])
             / c["proposed_cost_total"] * 100.0)
    crash_blob = {}
    if crash_at != 0:
        crash_tick = (crash_at if crash_at is not None
                      else max(1, w["ticks"] // 2))
        with tempfile.TemporaryDirectory() as tmp:
            rec, crashed = replay_with_crash(
                RightsizingService(),
                list(trace), crash_after_ticks=crash_tick,
                snapshot_dir=os.path.join(tmp, "snap"),
                push_per_tick=push)
        crash_blob = {
            "crash_at_tick": crash_tick,
            "crashed": crashed,
            "recovered_ticks": rec["ticks"],
            "recovered_total_cost": rec["total_cost"],
            "recovered_proposed_cost_total": rec["proposed_cost_total"],
            "warm_frac": _warm_frac(w),
            "recovered_warm_frac": _warm_frac(rec),
        }
    return {
        "scale": scale,
        "seed": seed,
        "trace": "gct",
        "fleets": fleets,
        "requests": w["requests"],
        "push_per_tick": push,
        "ticks": w["ticks"],
        "wall_s": walls["warm"],
        "requests_per_s": w["requests_per_s"],
        "p50_replan_s": w["p50_replan_s"],
        "p99_replan_s": w["p99_replan_s"],
        "dispatches_per_tick": w["dispatches_per_tick"],
        "cold_dispatches_per_tick": c["dispatches_per_tick"],
        "warm_lanes": w["warm_lanes"],
        "cold_lanes": w["cold_lanes"],
        "drift_fallbacks": w["drift_fallbacks"],
        "median_iters_warm": w["median_iters_warm"],
        "median_iters_admit": w["median_iters_admit"],
        "median_iters_cold_control": c["median_iters_cold"],
        "converged_frac": w["converged_frac"],
        "cold_converged_frac": c["converged_frac"],
        "events": w["events"],
        "total_cost": w["total_cost"],
        "cold_total_cost": c["total_cost"],
        "proposed_cost_total": w["proposed_cost_total"],
        "cold_proposed_cost_total": c["proposed_cost_total"],
        "proposed_cost_drift_pct": round(drift, 4),
        "cost_drift_bound_pct":
            ServiceConfig().cost_drift_bound_pct,
        **crash_blob,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=sorted(_SCALES), default="quick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="tick to crash-and-recover at (default: "
                         "mid-replay; 0 disables the crash leg)")
    ap.add_argument("--out", default=None,
                    help="merge the blob under the 'serve' key of "
                         "<out>/solver_stats.json (default: print only)")
    args = ap.parse_args(argv)
    blob = serve_smoke(scale=args.scale, seed=args.seed,
                       crash_at=args.crash_at)
    print(json.dumps(blob, indent=2))
    if args.out:
        path = os.path.join(args.out, "solver_stats.json")
        stats = {}
        if os.path.exists(path):
            with open(path) as f:
                stats = json.load(f)
        stats["serve"] = blob
        os.makedirs(args.out, exist_ok=True)
        with open(path, "w") as f:
            json.dump(stats, f, indent=1)
        print(f"# serve telemetry merged -> {path}")


if __name__ == "__main__":
    main()
