"""Service-regression gate for the online rightsizing loop.

    python -m benchmarks.check_service results/ci/solver_stats.json \
        results/golden/solver_stats.json [--max-cost-drift 2.0]

Reads the ``serve`` telemetry blob that ``benchmarks.run
--serve-trace`` (via ``benchmarks.serve_smoke``) merges into
``solver_stats.json`` and holds the serving loop's contracts:

  * micro-batching invariant: every tick coalesced its touched fleets
    into exactly ONE FleetEngine dispatch, warm and cold runs alike;
  * every lane of every tick converged to tolerance;
  * warm advantage: the median iterations of warm re-solves must stay
    below the cold control's matched re-solves (the whole point of
    carrying ``PDHGState`` across ticks);
  * warm-vs-cold parity: the proposed placement-cost totals of the
    paired replays agree within ``ServiceConfig.cost_drift_bound_pct``
    (recorded in the blob; override with ``--max-cost-drift``) — both
    runs propose from identical per-tick problems, so drift beyond
    epsilon-optimal vertex noise means a warm-start correctness bug;
  * determinism vs the committed baseline: same trace spec => same
    request/tick counts and the adopted ``total_cost`` within the same
    parity budget;
  * throughput floor and p99 re-plan latency ceiling vs the baseline
    (generous factors — CI machines vary, real regressions are 10x);
  * crash-and-recover determinism (when the blob carries the crash
    leg's keys): a replay interrupted mid-trace — checkpointed,
    discarded, restored from disk, finished — must adopt the SAME
    total cost as the uninterrupted run (snapshots round-trip floats
    exactly, so the tolerance is numerical noise, not a budget), run
    the same number of ticks, and keep its warm-lane fraction within
    ``--max-warm-frac-drop`` of the uninterrupted run's (warm
    ``PDHGState`` chains must survive the restart).

Exit code 0 on pass, 1 on regression — wired as a CI step right after
the convergence gate.  Regenerate the baseline intentionally by
re-running the smoke with ``--serve-trace`` and copying the fresh
``solver_stats.json`` over ``results/golden/``.
"""

from __future__ import annotations

import argparse
import json
import sys


def check(cur: dict, base: dict, max_cost_drift: float | None = None,
          min_rps_factor: float = 0.2,
          max_p99_factor: float = 5.0,
          max_warm_frac_drop: float = 0.05) -> list[str]:
    """Returns the list of regression messages (empty == gate passes)."""
    errs = []
    bound = (max_cost_drift if max_cost_drift is not None
             else cur["cost_drift_bound_pct"])
    for key in ("dispatches_per_tick", "cold_dispatches_per_tick"):
        if cur[key] != 1:
            errs.append(
                f"micro-batching invariant broken: {key} == "
                f"{cur[key]} (every tick must coalesce its touched "
                f"fleets into ONE FleetEngine dispatch)")
    for key in ("converged_frac", "cold_converged_frac"):
        if cur[key] < 1.0:
            errs.append(
                f"unconverged lanes: {key} == {cur[key]:.4f} < 1.0")
    if cur["median_iters_warm"] >= cur["median_iters_cold_control"]:
        errs.append(
            f"warm re-solves lost their iteration advantage: median "
            f"{cur['median_iters_warm']} >= cold control "
            f"{cur['median_iters_cold_control']}")
    if cur["proposed_cost_drift_pct"] > bound:
        errs.append(
            f"warm-vs-cold proposed-cost parity broken: drift "
            f"{cur['proposed_cost_drift_pct']:.3f}% > "
            f"bound {bound}%")
    for key in ("requests", "ticks", "fleets"):
        if cur[key] != base[key]:
            errs.append(
                f"replay shape changed vs baseline: {key} "
                f"{cur[key]} != {base[key]} (same TraceSpec must "
                f"yield the same deterministic replay)")
    drift = (abs(cur["total_cost"] - base["total_cost"])
             / base["total_cost"] * 100.0)
    if drift > bound:
        errs.append(
            f"adopted total_cost drifted {drift:.3f}% vs baseline "
            f"{base['total_cost']} (budget {bound}%)")
    rps_floor = base["requests_per_s"] * min_rps_factor
    if cur["requests_per_s"] < rps_floor:
        errs.append(
            f"sustained throughput collapsed: {cur['requests_per_s']} "
            f"req/s < {rps_floor:.2f} ({min_rps_factor}x baseline "
            f"{base['requests_per_s']})")
    p99_ceiling = base["p99_replan_s"] * max_p99_factor
    if cur["p99_replan_s"] > p99_ceiling:
        errs.append(
            f"p99 re-plan latency blew up: {cur['p99_replan_s']}s > "
            f"{p99_ceiling:.2f}s ({max_p99_factor}x baseline "
            f"{base['p99_replan_s']}s)")
    if "recovered_total_cost" in cur:
        errs.extend(check_crash_recovery(
            cur, max_warm_frac_drop=max_warm_frac_drop))
    return errs


def check_crash_recovery(cur: dict,
                         max_warm_frac_drop: float = 0.05) -> list[str]:
    """The crash-and-recover gate: the interrupted replay must be
    indistinguishable from the uninterrupted one (cost-exact; warm
    lanes survive the restart).  Internal to the current blob — no
    baseline needed."""
    errs = []
    if not cur.get("crashed", False):
        errs.append(
            f"crash leg never crashed: the trace drained in "
            f"{cur['recovered_ticks']} tick(s) before crash_at_tick="
            f"{cur['crash_at_tick']} — lower --crash-at so the gate "
            f"actually exercises recovery")
        return errs
    for key in ("total_cost", "proposed_cost_total"):
        got, want = cur[f"recovered_{key}"], cur[key]
        # snapshots round-trip floats exactly; the only slack is the
        # blob's own 6-decimal rounding
        if abs(got - want) > 1e-9 * max(1.0, abs(want)) + 2e-6:
            errs.append(
                f"crash-and-recover replay diverged: recovered_{key} "
                f"{got} != uninterrupted {want} (snapshot/restore must "
                f"be bit-exact)")
    if cur["recovered_ticks"] != cur["ticks"]:
        errs.append(
            f"crash-and-recover replay ran {cur['recovered_ticks']} "
            f"tick(s) vs the uninterrupted {cur['ticks']} (restored "
            f"queue/fleet state must resume the same schedule)")
    drop = cur["warm_frac"] - cur["recovered_warm_frac"]
    if drop > max_warm_frac_drop:
        errs.append(
            f"warm lanes did not survive the restart: recovered warm "
            f"fraction {cur['recovered_warm_frac']} vs uninterrupted "
            f"{cur['warm_frac']} (allowed drop {max_warm_frac_drop})")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="solver_stats.json from this run")
    ap.add_argument("baseline", help="committed baseline solver_stats.json")
    ap.add_argument("--max-cost-drift", type=float, default=None,
                    help="allowed warm-vs-cold / vs-baseline cost "
                         "drift in percent (default: the blob's "
                         "recorded ServiceConfig.cost_drift_bound_pct)")
    ap.add_argument("--min-rps-factor", type=float, default=0.2,
                    help="throughput floor as a fraction of the "
                         "baseline requests/sec (default 0.2)")
    ap.add_argument("--max-p99-factor", type=float, default=5.0,
                    help="p99 re-plan latency ceiling as a factor of "
                         "the baseline (default 5.0)")
    ap.add_argument("--max-warm-frac-drop", type=float, default=0.05,
                    help="allowed warm-lane-fraction drop of the "
                         "crash-and-recover replay vs the "
                         "uninterrupted run (default 0.05)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f).get("serve")
    with open(args.baseline) as f:
        base = json.load(f).get("serve")
    if cur is None:
        print("FAIL: no 'serve' key in current solver_stats.json — "
              "run benchmarks.run with --serve-trace", file=sys.stderr)
        return 1
    if base is None:
        print("FAIL: no 'serve' key in baseline solver_stats.json — "
              "regenerate results/golden/solver_stats.json",
              file=sys.stderr)
        return 1

    errs = check(cur, base, args.max_cost_drift, args.min_rps_factor,
                 args.max_p99_factor, args.max_warm_frac_drop)
    print(f"service gate: {cur['requests']} requests / {cur['ticks']} "
          f"ticks, {cur['requests_per_s']} req/s, p99 "
          f"{cur['p99_replan_s']}s, dispatches/tick "
          f"{cur['dispatches_per_tick']}, warm median "
          f"{cur['median_iters_warm']} vs cold control "
          f"{cur['median_iters_cold_control']}, proposed-cost drift "
          f"{cur['proposed_cost_drift_pct']}%")
    if "recovered_total_cost" in cur:
        print(f"crash-recover gate: crashed at tick "
              f"{cur['crash_at_tick']}, recovered cost "
              f"{cur['recovered_total_cost']} vs {cur['total_cost']}, "
              f"warm frac {cur['recovered_warm_frac']} vs "
              f"{cur['warm_frac']}")
    if errs:
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("service gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
